"""Online serving loop: continuous ingestion, streaming, adaptation.

``Cluster.run`` replays a pre-materialized request list and returns when
the heap drains — fine for goodput sweeps, useless for serving.
``ServingLoop`` drives the same event core *incrementally*:

* **open-loop ingestion** — arrivals come from an iterator (e.g.
  ``PhaseDriftSpec.iter_requests``) and are submitted one ahead of the
  event horizon, so the trace is never materialized and the workload can
  drift (or be generated live) while the loop runs;
* **streaming** — every emitted token fires per-request and global
  callbacks (``Instance.token_sink``), and each submitted request gets a
  ``RequestHandle`` future that resolves at finish/rejection;
* **telemetry** — token/finish/reject events feed a
  ``TelemetryWindow`` (windowed attainment, goodput, gauges), with
  periodic snapshots accumulated in a ``MetricsLog``;
* **adaptation** — an attached ``SliderController`` observes windowed
  headroom at epoch boundaries and retunes chunk sizes or stages
  drain-and-flip role changes through the cluster's migration machinery.

The loop is executor-agnostic: with ``SimExecutor`` it is a
deterministic virtual-clock simulation; with ``JaxExecutor`` the same
schedule computes real tokens (``--engine live``), optionally paced to
wall time by ``WallClock``.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
from typing import Callable, Dict, Iterable, Iterator, List, Optional

from repro.core.cluster import ARRIVAL, COMMIT, Cluster
from repro.core.instance import (HEALTH_OK, HEALTH_QUARANTINED, Instance)
from repro.core.latency import SLO, RunStats
from repro.engine.request import Request, State
from repro.frontend.admission import AdmissionConfig, AdmissionQueue
from repro.serving.clock import VirtualClock, WallClock
from repro.serving.faults import FaultInjector
from repro.serving.metrics import MetricsLog, TelemetryWindow
from repro.serving.tracing import (PH_ADMISSION, PH_QUEUE, TraceConfig,
                                   Tracer)

_DONE_STATES = (State.FINISHED, State.REJECTED, State.CANCELLED,
                State.FAILED)


@dataclasses.dataclass
class WatchdogConfig:
    """Stall/heartbeat detection and probation-based re-admission.

    ``heartbeat_timeout`` is EVENT time: an instance whose dispatched
    step runs this far past its cost-model deadline (``step_deadline``)
    is quarantined — injected stalls and real slowdowns both trip it.
    ``stall_timeout`` is WALL time (live executors only): a COMMIT whose
    ``PendingStep`` still isn't ready this long past the modeled end
    quarantines the instance instead of blocking the loop on
    ``resolve()``.  Quarantined instances re-admit after ``probation``
    seconds, doubling per repeat offense up to ``max_probation``."""
    heartbeat_timeout: float = 2.0
    stall_timeout: float = 2.0
    probation: float = 5.0
    probation_backoff: float = 2.0
    max_probation: float = 60.0
    check_every: float = 0.25


class RequestHandle:
    """Future for one submitted request: resolves when the request
    finishes (or is rejected/cancelled); streams tokens as they are
    emitted."""

    def __init__(self, req: Request,
                 on_token: Optional[Callable] = None):
        self.req = req
        self.tokens: List[tuple] = []        # (time, token_id | None)
        self._on_token = on_token
        #: resolve notification (network front-end: triggers the final
        #: response frames) — called exactly once, from the loop thread
        self.on_done: Optional[Callable[[Request], None]] = None
        self._resolved = False

    @property
    def done(self) -> bool:
        return self.req.state in _DONE_STATES

    @property
    def rejected(self) -> bool:
        return self.req.state == State.REJECTED

    @property
    def cancelled(self) -> bool:
        return self.req.state == State.CANCELLED

    @property
    def failed(self) -> bool:
        return self.req.state == State.FAILED

    def result(self) -> Request:
        if not self.done:
            raise RuntimeError(
                f"request {self.req.rid} still {self.req.state.value}; "
                "drive the loop further")
        return self.req

    def _emit(self, t: float, tok: Optional[int]):
        self.tokens.append((t, tok))
        if self._on_token is not None:
            self._on_token(self.req, t, tok)

    def _resolve(self):
        if not self._resolved:
            self._resolved = True
            if self.on_done is not None:
                self.on_done(self.req)


@dataclasses.dataclass
class SubmitMsg:
    """One externally-submitted request crossing the thread boundary
    into the loop (the HTTP gateway produces these).  ``receipt`` is
    the wall/clock time the connection actually delivered the request
    — arrival truth for TTFT and queue-wait accounting."""
    req: Request
    priority: Optional[str] = None
    receipt: Optional[float] = None
    on_token: Optional[Callable] = None
    reply: Optional[Callable[["RequestHandle"], None]] = None


@dataclasses.dataclass
class AbortMsg:
    """Client-disconnect propagation: the gateway enqueues one of these
    when an SSE connection drops; the loop aborts the request in the
    engine and frees its blocks.  A no-op if the request already
    resolved (normal completion also closes the connection)."""
    rid: int


class ServingLoop:
    def __init__(self, cluster: Cluster, slo: SLO,
                 arrivals: Optional[Iterable[Request]] = None,
                 clock: Optional[VirtualClock] = None,
                 controller=None, window: float = 10.0,
                 on_token: Optional[Callable] = None,
                 snapshot_every: Optional[float] = None,
                 pace: bool = False, steal: bool = True,
                 admission: Optional[AdmissionConfig] = None,
                 watchdog: Optional[WatchdogConfig] = None,
                 faults: Optional[FaultInjector] = None,
                 tracing: Optional[TraceConfig] = None):
        self.cluster = cluster
        self.slo = slo
        self.clock = clock or VirtualClock()
        self.telemetry = TelemetryWindow(slo, window=window)
        # rates divide by seconds OBSERVED: the loop's start time is the
        # window's origin (0.0 in simulation — unchanged spans there; a
        # wall clock that starts mid-epoch no longer inflates the
        # denominator of its first snapshots)
        self.telemetry.anchor(cluster.now)
        self.log = MetricsLog()
        self.controller = controller
        self._arrivals: Optional[Iterator[Request]] = (
            iter(arrivals) if arrivals is not None else None)
        self._handles: Dict[int, RequestHandle] = {}
        self.requests: List[Request] = []     # every request ever seen
        self._global_on_token = on_token
        self._snapshot_every = snapshot_every
        self._next_snapshot = snapshot_every
        self._pace = pace
        self._steal = steal
        # router-side admission queue (None = legacy immediate routing)
        self.admission: Optional[AdmissionQueue] = (
            AdmissionQueue(admission) if admission is not None else None)
        self._released: set = set()     # rids admitted past the queue
        self._inflight = 0
        self.shed_rejections = 0
        self.cancelled_count = 0
        # serving-mode ingress: externally-submitted requests cross the
        # thread boundary here (created lazily by ``serve``/``ingress``)
        self._ingress: Optional[_queue.Queue] = None
        self._serving = False
        self._refusing = False       # graceful drain: cancel stragglers
        # fault tolerance: watchdog (stall/heartbeat detection +
        # probation re-admission) and optional fault injection
        self.watchdog = watchdog
        self._next_watchdog = 0.0
        self._probation_until: Dict[int, float] = {}
        self._strikes: Dict[int, int] = {}
        self.aborted_count = 0
        self.failed_count = 0
        # request-lifecycle tracing (off by default: every call site
        # guards on ``tracer is None``, so an untraced run takes the
        # exact pre-tracing path)
        if tracing is None:
            self.tracer: Optional[Tracer] = None
        else:
            self.tracer = (tracing if isinstance(tracing, Tracer)
                           else Tracer(tracing))
            cluster.tracer = self.tracer
            for inst in cluster.instances:
                inst.tracer = self.tracer
        for inst in cluster.instances:
            inst.token_sink = self._token_sink
        cluster.on_finish = self._on_finish
        cluster.on_reject = self._on_reject
        cluster.on_failed = self._on_failed
        cluster.on_abort = self._on_abort
        if faults is not None:
            cluster.attach_faults(faults)
        if controller is not None:
            controller.bind(self)

    # ------------------------------------------------------------------
    # ingestion
    # ------------------------------------------------------------------
    def submit(self, req: Request,
               on_token: Optional[Callable] = None,
               priority: Optional[str] = None,
               receipt: Optional[float] = None) -> RequestHandle:
        """Submit one request (external callers; the arrival iterator
        and the network ingress feed through here too).  Returns its
        streaming future.

        Arrival stamping: a ``receipt`` (actual connection-receipt
        time, or the workload generator's intended arrival) is
        PRESERVED as ``req.arrival`` even when the loop is running
        behind — the heap event is clamped to now so events never land
        behind the clock, but TTFT and queue-wait measure from when
        the request really arrived, not from when the loop got around
        to drawing it.  Without a receipt (bare external submission,
        arrival defaulting to 0.0) the request arrives NOW."""
        if receipt is not None:
            req.arrival = receipt
        else:
            req.arrival = max(req.arrival, self.cluster.now)
        if priority is not None:
            req.priority = priority
        handle = RequestHandle(req, on_token)
        self._handles[req.rid] = handle
        self.requests.append(req)
        if self.tracer is not None:
            self.tracer.begin(req, req.arrival,
                              PH_ADMISSION if self.admission is not None
                              else PH_QUEUE)
        if self.admission is not None:
            self._enqueue_admission(req, priority)
        else:
            self.cluster.submit(req, t=max(req.arrival, self.cluster.now))
        return handle

    def _pump_arrival(self) -> bool:
        """Keep exactly one not-yet-processed arrival in the event heap
        (arrivals are nondecreasing in time, so one look-ahead preserves
        event order while staying incremental)."""
        if self._arrivals is None:
            return False
        req = next(self._arrivals, None)
        if req is None:
            self._arrivals = None
            return False
        # the generator's timestamp is the arrival truth — the pump's
        # draw time must not rewrite it (wall-clock pacing: a loop
        # running behind draws bursts late, and clamping arrivals to
        # the draw would silently shrink measured queue wait and TTFT)
        self.submit(req, receipt=req.arrival)
        return True

    # ------------------------------------------------------------------
    # router-side admission queue
    # ------------------------------------------------------------------
    def _enqueue_admission(self, req: Request, priority: Optional[str]):
        q = self.admission
        ok, displaced = q.push(req, q.resolve_class(priority),
                               max(req.arrival, self.cluster.now))
        for entry in displaced:
            self._finish_unserved(entry.req, State.REJECTED)
        if not ok:
            self._finish_unserved(req, State.REJECTED)
        self._release_admission()

    def _release_admission(self):
        """Move queued work into the cluster while the released
        population is under the in-flight cap — the admission queue
        absorbs the burst, the instance queues stay near their
        sustainable depth."""
        q = self.admission
        if q is None:
            return
        now = self.cluster.now
        while len(q) and self._inflight < q.cfg.max_inflight:
            entry = q.pop(now)
            if entry is None:
                # every queued class is over its token budget for the
                # current window — nothing releasable this tick
                break
            self._inflight += 1
            self._released.add(entry.req.rid)
            self.telemetry.on_queue_wait(
                now, max(now - entry.enq_time, 0.0))
            if self.tracer is not None:
                self.tracer.phase(entry.req.rid, now, PH_QUEUE,
                                  cls=entry.cls)
            self.cluster.submit(entry.req,
                                t=max(entry.req.arrival, now))

    def _finish_unserved(self, req: Request, state: State):
        """Resolve a request that will never reach the cluster
        (displaced/shed -> REJECTED, drained at shutdown ->
        CANCELLED)."""
        now = self.cluster.now
        req.state = state
        req.finish_time = now
        if state == State.REJECTED:
            self.shed_rejections += 1
            self.telemetry.on_reject(req, now)
        else:
            self.cancelled_count += 1
            self.telemetry.on_cancel(req, now)
        if self.tracer is not None:
            self.tracer.finish(req, now)
        handle = self._handles.get(req.rid)
        if handle is not None:
            handle._resolve()

    def shed_admission(self, fraction: Optional[float] = None) -> int:
        """Admission control as an actuator (SliderController, both
        dimensions starved): early-reject queued work from the lowest
        priority classes up.  Returns how many were shed."""
        if self.admission is None:
            return 0
        entries = self.admission.shed(fraction)
        for e in entries:
            self._finish_unserved(e.req, State.REJECTED)
        if entries:
            self.log.record_event(self.cluster.now, "shed", {
                "count": len(entries),
                "classes": sorted({e.cls for e in entries})})
            if self.tracer is not None:
                self.tracer.global_event(self.cluster.now, "shed",
                                         count=len(entries))
        return len(entries)

    def cancel_queued(self) -> int:
        """Graceful drain: everything still in the admission queue
        resolves CANCELLED (in-flight work keeps running to
        completion)."""
        if self.admission is None:
            return 0
        entries = self.admission.drain()
        for e in entries:
            self._finish_unserved(e.req, State.CANCELLED)
        return len(entries)

    # ------------------------------------------------------------------
    # serving-mode ingress (thread boundary to the network front-end)
    # ------------------------------------------------------------------
    @property
    def ingress(self) -> _queue.Queue:
        """Thread-safe submission queue for ``SubmitMsg`` items; the
        loop drains it every cycle while ``serve`` runs."""
        if self._ingress is None:
            self._ingress = _queue.Queue()
        return self._ingress

    def receipt_now(self) -> float:
        """Arrival stamp for an externally-received request: wall time
        under a ``WallClock`` (the connection's actual receipt), the
        event clock otherwise."""
        if isinstance(self.clock, WallClock):
            return self.clock.now
        return self.cluster.now

    def _ingress_pending(self) -> bool:
        return self._ingress is not None and not self._ingress.empty()

    def _submit_msg(self, msg: SubmitMsg):
        if self._refusing:
            # graceful drain already began: never start new work
            handle = RequestHandle(msg.req, msg.on_token)
            self._handles[msg.req.rid] = handle
            self.requests.append(msg.req)
            self._finish_unserved(msg.req, State.CANCELLED)
            if msg.reply is not None:
                msg.reply(handle)
            return
        handle = self.submit(msg.req, on_token=msg.on_token,
                             priority=msg.priority, receipt=msg.receipt)
        if msg.reply is not None:
            msg.reply(handle)

    def _ingress_msg(self, msg):
        if isinstance(msg, AbortMsg):
            self.abort(msg.rid)
        else:
            self._submit_msg(msg)

    def _drain_ingress(self):
        if self._ingress is None:
            return
        while True:
            try:
                msg = self._ingress.get_nowait()
            except _queue.Empty:
                return
            self._ingress_msg(msg)

    # ------------------------------------------------------------------
    # event plumbing
    # ------------------------------------------------------------------
    def _token_sink(self, req: Request, t: float):
        self.telemetry.on_token(req, t)
        handle = self._handles.get(req.rid)
        tok = req.output_tokens[-1] if req.output_tokens else None
        if handle is not None:
            handle._emit(t, tok)
        if self._global_on_token is not None:
            self._global_on_token(req, t, tok)

    def _on_finish(self, req: Request, t: float):
        self.telemetry.on_finish(req, t)
        self._retire(req)

    def _on_reject(self, req: Request, t: float):
        self.telemetry.on_reject(req, t)
        self._retire(req)

    def _on_failed(self, req: Request, t: float):
        self.telemetry.on_failed(req, t)
        self.failed_count += 1
        self._retire(req)

    def _on_abort(self, req: Request, t: float):
        self.telemetry.on_abort(req, t)
        self.aborted_count += 1
        self._retire(req)

    # ------------------------------------------------------------------
    # client-initiated abort (disconnect propagation)
    # ------------------------------------------------------------------
    def abort(self, rid: int) -> bool:
        """Abort one submitted request: pulled straight out of the
        admission queue if unreleased, otherwise handed to the cluster's
        safe-boundary abort machinery (it frees KV blocks the moment the
        request is not mid-flight).  Idempotent; True once the request
        is terminally resolved or the abort is staged."""
        handle = self._handles.get(rid)
        if handle is None:
            return False
        req = handle.req
        if req.state in _DONE_STATES:
            return True
        if self.admission is not None and rid not in self._released:
            entry = self.admission.remove(rid)
            if entry is not None:
                now = self.cluster.now
                req.state = State.CANCELLED
                req.finish_reason = "abort"
                req.finish_time = now
                self.aborted_count += 1
                self.telemetry.on_abort(req, now)
                if self.tracer is not None:
                    self.tracer.finish(req, now)
                handle._resolve()
                return True
        return self.cluster.abort_request(req)

    def _retire(self, req: Request):
        """A released request left the system: free its admission slot
        (pulling the next queued request in) and resolve its handle."""
        if self.tracer is not None:
            self.tracer.finish(req, req.finish_time
                               if req.finish_time is not None
                               else self.cluster.now)
        if req.rid in self._released:
            self._released.discard(req.rid)
            self._inflight -= 1
            self._release_admission()
        handle = self._handles.get(req.rid)
        if handle is not None:
            handle._resolve()

    # ------------------------------------------------------------------
    # control surface (used by SliderController; callable directly)
    # ------------------------------------------------------------------
    def flip_role(self, inst: Instance, itype: str,
                  chunk_size: int) -> bool:
        staged = self.cluster.request_role_flip(inst, itype, chunk_size)
        if staged:
            self.log.record_event(self.cluster.now, "role_flip", {
                "iid": inst.iid, "to": itype, "chunk": chunk_size})
        return staged

    def set_chunks(self, itype: str, chunk_size: int) -> int:
        """Retune the chunk-size slider for every ``itype`` instance
        (instantaneous — chunk size is a per-iteration budget, so no
        drain is needed).  Returns how many instances changed."""
        n = 0
        for inst in self.cluster.instances:
            if inst.itype == itype and not inst.draining \
                    and inst.chunk_size != chunk_size:
                inst.chunk_size = chunk_size
                n += 1
                if chunk_size <= 0 and inst.prefill_queue:
                    # a pure-decode instance can never drain its prefill
                    # queue — hand the queued (not-yet-admitted) work
                    # back to the router with full ARRIVAL semantics
                    # (early rejection included)
                    requeue = [r for r in inst.prefill_queue
                               if not inst.allocator.holds(r.rid)]
                    for r in requeue:
                        inst.prefill_queue.remove(r)
                        self.cluster.reroute(r)
        if n:
            self.log.record_event(self.cluster.now, "set_chunk", {
                "itype": itype, "chunk": chunk_size, "instances": n})
        return n

    # ------------------------------------------------------------------
    # watchdog: stall/heartbeat detection + probation re-admission
    # ------------------------------------------------------------------
    def _start_probation(self, inst: Instance, now: float) -> float:
        """Schedule the quarantined instance's re-admission, doubling
        the probation per repeat offense up to the cap."""
        wd = self.watchdog
        strikes = self._strikes.get(inst.iid, 0)
        self._strikes[inst.iid] = strikes + 1
        probation = min(wd.probation * wd.probation_backoff ** strikes,
                        wd.max_probation)
        until = now + probation
        self._probation_until[inst.iid] = until
        return probation

    def _quarantine(self, inst: Instance, now: float, why: str):
        self.cluster.quarantine_instance(inst, now, reason=why)
        probation = self._start_probation(inst, now)
        self.log.record_event(now, "quarantine", {
            "iid": inst.iid, "why": why,
            "probation_s": round(probation, 3)})

    def _watchdog_check(self, now: float):
        """Periodic health sweep: quarantine instances whose dispatched
        step ran past its cost-model deadline by ``heartbeat_timeout``
        (missed heartbeat — stalls and slowdowns), and re-admit
        quarantined instances whose probation has elapsed.  Instances
        the CLUSTER quarantined on its own (executor exceptions) get a
        probation clock here too — the watchdog owns all re-admission."""
        wd = self.watchdog
        if wd is None or now < self._next_watchdog:
            return
        self._next_watchdog = now + wd.check_every
        for inst in self.cluster.instances:
            if inst.health == HEALTH_OK:
                if now > inst.step_deadline + wd.heartbeat_timeout:
                    self._quarantine(inst, now, "heartbeat")
                elif inst.overrun > wd.heartbeat_timeout:
                    # sync-executor heartbeat: dispatch+commit happen in
                    # one atomic event, so a stall never leaves a live
                    # step_deadline behind for the sweep above to catch.
                    # The instance records how far each dispatch ran
                    # past its cost-model duration; an overrun past the
                    # timeout is the same missed heartbeat, observed
                    # after the fact.
                    inst.overrun = 0.0
                    self._quarantine(inst, now, "overrun")
            elif inst.health == HEALTH_QUARANTINED:
                until = self._probation_until.get(inst.iid)
                if until is None:          # cluster-initiated quarantine
                    until = now + self._start_probation(inst, now)
                if now >= until and self.cluster.recover_instance(inst,
                                                                  now):
                    self._probation_until.pop(inst.iid, None)
                    self.log.record_event(now, "readmit",
                                          {"iid": inst.iid})
                    if self.tracer is not None:
                        self.tracer.global_event(now, "readmit",
                                                 iid=inst.iid)

    def _stall_check(self) -> bool:
        """Live-path stall guard: when the next event is a COMMIT whose
        async step STILL is not device-ready ``stall_timeout`` wall
        seconds past its modeled end, quarantine the instance instead of
        letting ``PendingStep.resolve`` block the loop forever.  Returns
        True when it intervened (the caller re-peeks: the COMMIT is now
        stale and the evacuated work has been rerouted)."""
        wd = self.watchdog
        if wd is None or not isinstance(self.clock, WallClock):
            return False
        ev = self.cluster.peek_event()
        if ev is None or ev[1] != COMMIT:
            return False
        inst = self.cluster._inst_by_id[ev[2]]
        pending = inst.pending_step()
        if pending is None or pending.ready():
            return False
        if self.clock.now < inst.step_deadline + wd.stall_timeout:
            return False
        self._quarantine(inst, self.cluster.now, "stall")
        return True

    def _steal_prefill(self):
        """Online-runtime load repair: an idle prefill-capable instance
        pulls queued-but-unadmitted prefill work from the deepest peer
        queue.  Routing decisions pile up behind a slow configuration
        (e.g. the queue an instance accumulated before a slider move);
        stealing lets spare capacity drain the backlog instead of
        leaving it pinned to the original placement."""
        insts = [i for i in self.cluster.instances if i.schedulable]
        if len(insts) < 2:
            return
        idle = [i for i in insts
                if i.chunk_size > 0 and not i.prefill_queue
                and not i.decoding and not i.pending_decode]
        if not idle:
            return
        # one queue-depth scan per call, not per thief — this runs after
        # every event, so it must be cheap when there is nothing to do
        depths = {i.iid: i.queued_prefill_tokens() for i in insts}
        for thief in idle:
            victim = max(insts, key=lambda i: depths[i.iid])
            if depths[victim.iid] == 0 or len(victim.prefill_queue) < 2:
                return                 # no queue anywhere worth raiding
            # steal from the tail: the head may be mid-chunk/admitted
            req = victim.prefill_queue[-1]
            if victim.allocator.holds(req.rid):
                continue
            victim.prefill_queue.pop()
            depths[victim.iid] -= req.prefill_remaining
            depths[thief.iid] = req.prefill_remaining
            thief.enqueue_prefill(req)
            self.cluster._schedule_iter(thief, self.cluster.now)

    # ------------------------------------------------------------------
    # pacing: wait on either the next event OR horizon completion
    # ------------------------------------------------------------------
    #: wall-clock slice between pipeline-readiness polls while pacing
    PACE_SLICE = 0.005

    def _pending_steps(self):
        """Unresolved async executor steps currently in flight."""
        return [p for inst in self.cluster.instances
                if (p := inst.pending_step()) is not None]

    def _prefetch_ready(self, pending) -> None:
        for p in pending:
            if not p.resolved and p.ready():
                p.prefetch()

    def _pace_until(self, t: float) -> bool:
        """Sleep to the next event time WITHOUT serializing ingestion
        behind compute: instead of one dead sleep, the gap is sliced and
        each slice polls the in-flight executor steps — the moment a
        horizon's device work completes, its results are prefetched to
        the host, so the commit event at ``t`` never blocks.  The wait
        ends on whichever comes first: the next scheduled event
        (arrival/commit/transfer), in-flight work becoming consumable,
        or a NEW network ingress submission (which may schedule an
        earlier arrival than ``t`` — the caller must re-peek).  Returns
        False when preempted by ingress, True when ``t`` was reached."""
        pending = self._pending_steps()
        slice_wait = isinstance(self.clock, WallClock) \
            and (pending or self._ingress is not None)
        if not slice_wait:
            # virtual time (or nothing that could preempt): plain jump —
            # but still harvest anything that already landed
            self._prefetch_ready(pending)
            self.clock.sleep_until(t)
            return True
        while True:
            self._prefetch_ready(pending)
            if self._ingress_pending():
                return False
            now = self.clock.now
            if now >= t:
                return True
            self.clock.sleep_until(min(t, now + self.PACE_SLICE))

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def run(self, until: Optional[float] = None,
            max_steps: Optional[int] = None) -> int:
        """Drive events until the system drains (arrivals exhausted and
        all work finished), ``until`` virtual seconds, or ``max_steps``
        events.  Returns the number of events processed; re-entrant —
        call again to continue."""
        steps = 0
        if self._arrivals is not None and not self.requests:
            self._pump_arrival()
        while max_steps is None or steps < max_steps:
            self._drain_ingress()
            self._watchdog_check(self.cluster.now)
            t = self.cluster.peek_time()
            if t is None:
                if not self._pump_arrival():
                    break
                continue
            if until is not None and t > until:
                break
            if self._pace and not self._pace_until(t):
                continue              # ingress preempted: re-peek
            if self._stall_check():
                continue              # quarantined a stalled step: re-peek
            stepped = self.cluster.step()
            if stepped is None:
                continue
            steps += 1
            _, kind, _ = stepped
            if kind == ARRIVAL:
                self._pump_arrival()
            elif self._steal:
                self._steal_prefill()
            now = self.cluster.now
            if self.controller is not None:
                self.controller.maybe_epoch(now)
            if self._snapshot_every is not None \
                    and now >= self._next_snapshot:
                self.log.record(self.snapshot(now))
                self._next_snapshot = (
                    now - now % self._snapshot_every + self._snapshot_every)
        return steps

    def snapshot(self, now: Optional[float] = None) -> dict:
        now = self.cluster.now if now is None else now
        snap = self.telemetry.snapshot(now, self.cluster.instances,
                                       admission=self.admission)
        # fault section only when something actually fired — a faults-off
        # run snapshots bit-identically to one without this layer at all
        fc = self.cluster.fault_counters()
        if any(fc.values()):
            snap["faults"] = fc
        if getattr(self.cluster, "recovery", None) is not None:
            snap["recovery"] = self.cluster.recovery_counters()
        return snap

    # ------------------------------------------------------------------
    # serving mode: run until told to stop, blocking on ingress when
    # idle (the network front-end drives this on a dedicated thread)
    # ------------------------------------------------------------------
    #: events per ``run`` slice in serving mode — small enough that a
    #: stop request is noticed promptly even mid-burst
    SERVE_SLICE = 256

    def serve(self, stop: threading.Event, idle_poll: float = 0.02):
        """Drive events indefinitely: drain the ingress every cycle,
        block briefly for new submissions when no work is pending, and
        on ``stop`` perform a graceful drain — stop ingesting (late
        stragglers resolve CANCELLED), resolve everything still queued
        in the admission queue as CANCELLED, and run the in-flight
        population to completion."""
        self._serving = True
        ingress = self.ingress          # materialize before clients race
        try:
            while not stop.is_set():
                self.run(max_steps=self.SERVE_SLICE)
                if self.cluster.peek_time() is None \
                        and not self._ingress_pending():
                    try:                # idle: wait for the next client
                        self._ingress_msg(ingress.get(timeout=idle_poll))
                    except _queue.Empty:
                        pass
            self._refusing = True
            self._drain_ingress()
            self.cancel_queued()
            self.run()                  # in-flight work finishes, SSE
        finally:                        # streams flush through on_token
            self._serving = False

    # ------------------------------------------------------------------
    def stats(self, qps: float) -> RunStats:
        moves = (self.controller.n_moves if self.controller is not None
                 else 0)
        st = self.cluster.stats(self.requests, self.slo, qps)
        st.slider_moves = moves
        st.early_rejections += self.shed_rejections
        return st
