"""Online serving loop: continuous ingestion, streaming, adaptation.

``Cluster.run`` replays a pre-materialized request list and returns when
the heap drains — fine for goodput sweeps, useless for serving.
``ServingLoop`` drives the same event core *incrementally*:

* **open-loop ingestion** — arrivals come from an iterator (e.g.
  ``PhaseDriftSpec.iter_requests``) and are submitted one ahead of the
  event horizon, so the trace is never materialized and the workload can
  drift (or be generated live) while the loop runs;
* **streaming** — every emitted token fires per-request and global
  callbacks (``Instance.token_sink``), and each submitted request gets a
  ``RequestHandle`` future that resolves at finish/rejection;
* **telemetry** — token/finish/reject events feed a
  ``TelemetryWindow`` (windowed attainment, goodput, gauges), with
  periodic snapshots accumulated in a ``MetricsLog``;
* **adaptation** — an attached ``SliderController`` observes windowed
  headroom at epoch boundaries and retunes chunk sizes or stages
  drain-and-flip role changes through the cluster's migration machinery.

The loop is executor-agnostic: with ``SimExecutor`` it is a
deterministic virtual-clock simulation; with ``JaxExecutor`` the same
schedule computes real tokens (``--engine live``), optionally paced to
wall time by ``WallClock``.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Iterator, List, Optional

from repro.core.cluster import ARRIVAL, Cluster
from repro.core.instance import Instance
from repro.core.latency import SLO, RunStats
from repro.engine.request import Request, State
from repro.serving.clock import VirtualClock, WallClock
from repro.serving.metrics import MetricsLog, TelemetryWindow


class RequestHandle:
    """Future for one submitted request: resolves when the request
    finishes (or is rejected); streams tokens as they are emitted."""

    def __init__(self, req: Request,
                 on_token: Optional[Callable] = None):
        self.req = req
        self.tokens: List[tuple] = []        # (time, token_id | None)
        self._on_token = on_token

    @property
    def done(self) -> bool:
        return self.req.state in (State.FINISHED, State.REJECTED)

    @property
    def rejected(self) -> bool:
        return self.req.state == State.REJECTED

    def result(self) -> Request:
        if not self.done:
            raise RuntimeError(
                f"request {self.req.rid} still {self.req.state.value}; "
                "drive the loop further")
        return self.req

    def _emit(self, t: float, tok: Optional[int]):
        self.tokens.append((t, tok))
        if self._on_token is not None:
            self._on_token(self.req, t, tok)


class ServingLoop:
    def __init__(self, cluster: Cluster, slo: SLO,
                 arrivals: Optional[Iterable[Request]] = None,
                 clock: Optional[VirtualClock] = None,
                 controller=None, window: float = 10.0,
                 on_token: Optional[Callable] = None,
                 snapshot_every: Optional[float] = None,
                 pace: bool = False, steal: bool = True):
        self.cluster = cluster
        self.slo = slo
        self.clock = clock or VirtualClock()
        self.telemetry = TelemetryWindow(slo, window=window)
        # rates divide by seconds OBSERVED: the loop's start time is the
        # window's origin (0.0 in simulation — unchanged spans there; a
        # wall clock that starts mid-epoch no longer inflates the
        # denominator of its first snapshots)
        self.telemetry.anchor(cluster.now)
        self.log = MetricsLog()
        self.controller = controller
        self._arrivals: Optional[Iterator[Request]] = (
            iter(arrivals) if arrivals is not None else None)
        self._handles: Dict[int, RequestHandle] = {}
        self.requests: List[Request] = []     # every request ever seen
        self._global_on_token = on_token
        self._snapshot_every = snapshot_every
        self._next_snapshot = snapshot_every
        self._pace = pace
        self._steal = steal
        for inst in cluster.instances:
            inst.token_sink = self._token_sink
        cluster.on_finish = self._on_finish
        cluster.on_reject = self._on_reject
        if controller is not None:
            controller.bind(self)

    # ------------------------------------------------------------------
    # ingestion
    # ------------------------------------------------------------------
    def submit(self, req: Request,
               on_token: Optional[Callable] = None) -> RequestHandle:
        """Submit one request (external callers; the arrival iterator
        feeds through here too).  Returns its streaming future.  A
        request whose ``arrival`` lies in the loop's past (e.g. the
        default 0.0 on a mid-run external submission) arrives NOW —
        events never land behind the clock, and TTFT is measured from
        the actual submission time."""
        req.arrival = max(req.arrival, self.cluster.now)
        handle = RequestHandle(req, on_token)
        self._handles[req.rid] = handle
        self.requests.append(req)
        self.cluster.submit(req)
        return handle

    def _pump_arrival(self) -> bool:
        """Keep exactly one not-yet-processed arrival in the event heap
        (arrivals are nondecreasing in time, so one look-ahead preserves
        event order while staying incremental)."""
        if self._arrivals is None:
            return False
        req = next(self._arrivals, None)
        if req is None:
            self._arrivals = None
            return False
        self.submit(req)
        return True

    # ------------------------------------------------------------------
    # event plumbing
    # ------------------------------------------------------------------
    def _token_sink(self, req: Request, t: float):
        self.telemetry.on_token(req, t)
        handle = self._handles.get(req.rid)
        tok = req.output_tokens[-1] if req.output_tokens else None
        if handle is not None:
            handle._emit(t, tok)
        if self._global_on_token is not None:
            self._global_on_token(req, t, tok)

    def _on_finish(self, req: Request, t: float):
        self.telemetry.on_finish(req, t)

    def _on_reject(self, req: Request, t: float):
        self.telemetry.on_reject(req, t)

    # ------------------------------------------------------------------
    # control surface (used by SliderController; callable directly)
    # ------------------------------------------------------------------
    def flip_role(self, inst: Instance, itype: str,
                  chunk_size: int) -> bool:
        staged = self.cluster.request_role_flip(inst, itype, chunk_size)
        if staged:
            self.log.record_event(self.cluster.now, "role_flip", {
                "iid": inst.iid, "to": itype, "chunk": chunk_size})
        return staged

    def set_chunks(self, itype: str, chunk_size: int) -> int:
        """Retune the chunk-size slider for every ``itype`` instance
        (instantaneous — chunk size is a per-iteration budget, so no
        drain is needed).  Returns how many instances changed."""
        n = 0
        for inst in self.cluster.instances:
            if inst.itype == itype and not inst.draining \
                    and inst.chunk_size != chunk_size:
                inst.chunk_size = chunk_size
                n += 1
                if chunk_size <= 0 and inst.prefill_queue:
                    # a pure-decode instance can never drain its prefill
                    # queue — hand the queued (not-yet-admitted) work
                    # back to the router with full ARRIVAL semantics
                    # (early rejection included)
                    requeue = [r for r in inst.prefill_queue
                               if not inst.allocator.holds(r.rid)]
                    for r in requeue:
                        inst.prefill_queue.remove(r)
                        self.cluster.reroute(r)
        if n:
            self.log.record_event(self.cluster.now, "set_chunk", {
                "itype": itype, "chunk": chunk_size, "instances": n})
        return n

    def _steal_prefill(self):
        """Online-runtime load repair: an idle prefill-capable instance
        pulls queued-but-unadmitted prefill work from the deepest peer
        queue.  Routing decisions pile up behind a slow configuration
        (e.g. the queue an instance accumulated before a slider move);
        stealing lets spare capacity drain the backlog instead of
        leaving it pinned to the original placement."""
        insts = self.cluster.instances
        idle = [i for i in insts
                if i.chunk_size > 0 and not i.prefill_queue
                and not i.decoding and not i.pending_decode]
        if not idle:
            return
        # one queue-depth scan per call, not per thief — this runs after
        # every event, so it must be cheap when there is nothing to do
        depths = {i.iid: i.queued_prefill_tokens() for i in insts}
        for thief in idle:
            victim = max(insts, key=lambda i: depths[i.iid])
            if depths[victim.iid] == 0 or len(victim.prefill_queue) < 2:
                return                 # no queue anywhere worth raiding
            # steal from the tail: the head may be mid-chunk/admitted
            req = victim.prefill_queue[-1]
            if victim.allocator.holds(req.rid):
                continue
            victim.prefill_queue.pop()
            depths[victim.iid] -= req.prefill_remaining
            depths[thief.iid] = req.prefill_remaining
            thief.enqueue_prefill(req)
            self.cluster._schedule_iter(thief, self.cluster.now)

    # ------------------------------------------------------------------
    # pacing: wait on either the next event OR horizon completion
    # ------------------------------------------------------------------
    #: wall-clock slice between pipeline-readiness polls while pacing
    PACE_SLICE = 0.005

    def _pending_steps(self):
        """Unresolved async executor steps currently in flight."""
        return [p for inst in self.cluster.instances
                if (p := inst.pending_step()) is not None]

    def _prefetch_ready(self, pending) -> None:
        for p in pending:
            if not p.resolved and p.ready():
                p.prefetch()

    def _pace_until(self, t: float):
        """Sleep to the next event time WITHOUT serializing ingestion
        behind compute: instead of one dead sleep, the gap is sliced and
        each slice polls the in-flight executor steps — the moment a
        horizon's device work completes, its results are prefetched to
        the host, so the commit event at ``t`` never blocks.  The wait
        thus ends on whichever comes first matters: the next scheduled
        event (arrival/commit/transfer) or in-flight work becoming
        consumable."""
        pending = self._pending_steps()
        if not pending or not isinstance(self.clock, WallClock):
            # virtual time (or nothing in flight): a plain jump — but
            # still harvest anything that already landed
            self._prefetch_ready(pending)
            self.clock.sleep_until(t)
            return
        while True:
            self._prefetch_ready(pending)
            now = self.clock.now
            if now >= t:
                return
            self.clock.sleep_until(min(t, now + self.PACE_SLICE))

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def run(self, until: Optional[float] = None,
            max_steps: Optional[int] = None) -> int:
        """Drive events until the system drains (arrivals exhausted and
        all work finished), ``until`` virtual seconds, or ``max_steps``
        events.  Returns the number of events processed; re-entrant —
        call again to continue."""
        steps = 0
        if self._arrivals is not None and not self.requests:
            self._pump_arrival()
        while max_steps is None or steps < max_steps:
            t = self.cluster.peek_time()
            if t is None:
                if not self._pump_arrival():
                    break
                continue
            if until is not None and t > until:
                break
            if self._pace:
                self._pace_until(t)
            stepped = self.cluster.step()
            if stepped is None:
                continue
            steps += 1
            _, kind, _ = stepped
            if kind == ARRIVAL:
                self._pump_arrival()
            elif self._steal:
                self._steal_prefill()
            now = self.cluster.now
            if self.controller is not None:
                self.controller.maybe_epoch(now)
            if self._snapshot_every is not None \
                    and now >= self._next_snapshot:
                self.log.record(self.telemetry.snapshot(
                    now, self.cluster.instances))
                self._next_snapshot = (
                    now - now % self._snapshot_every + self._snapshot_every)
        return steps

    # ------------------------------------------------------------------
    def stats(self, qps: float) -> RunStats:
        moves = (self.controller.n_moves if self.controller is not None
                 else 0)
        st = self.cluster.stats(self.requests, self.slo, qps)
        st.slider_moves = moves
        return st
