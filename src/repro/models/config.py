"""Model configuration covering every assigned architecture family.

A single ``ModelConfig`` drives the unified decoder stack in
``repro.models.transformer``.  Families:

  dense   — GQA transformer (qwen2.5, qwen3, smollm, gemma3, llava/whisper backbones)
  moe     — dense attention + mixture-of-experts FFN (arctic, granite)
  ssm     — Mamba2/SSD mixer-only stack (mamba2-1.3b)
  hybrid  — Mamba2 backbone with shared attention blocks (zamba2)
  audio   — encoder-decoder transformer, stub conv/mel frontend (whisper)
  vlm     — dense decoder consuming stub patch embeddings (llava-next)

The per-layer *block pattern* is expressed as repeated *segments* so the
forward pass can ``lax.scan`` over homogeneous periods — HLO size stays
O(pattern) instead of O(num_layers), which keeps the 40-pair dry-run
compilable on one CPU core.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# Block type identifiers (strings keep the pattern pytree-static).
ATTN = "attn"            # full-attention transformer layer (attn + ffn)
ATTN_LOCAL = "attn_local"  # sliding-window attention layer (gemma3 local)
MOE = "moe"              # attention + MoE ffn (+ optional dense residual)
MAMBA2 = "mamba2"        # Mamba2/SSD mixer layer (no ffn when d_ff == 0)
ZAMBA_ATTN = "zamba_attn"  # shared-params attention block + own mamba2 layer
ENC = "enc"              # bidirectional encoder layer (whisper encoder)
DEC = "dec"              # decoder layer with self + cross attention (whisper)


@dataclasses.dataclass(frozen=True)
class Segment:
    """A run of ``n_periods`` repetitions of ``pattern`` (a tuple of block
    types).  Parameters for a segment are stacked with leading axis
    ``n_periods`` per pattern position, so the forward pass scans."""

    pattern: Tuple[str, ...]
    n_periods: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.n_periods


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention options ---
    qkv_bias: bool = False           # qwen2.5
    qk_norm: bool = False            # qwen3
    rope_theta: float = 1e6
    sliding_window: int = 0          # gemma3 local layers
    local_global_ratio: int = 0      # gemma3: N local layers per global

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # expert hidden size (d_ff used if 0)
    dense_residual: bool = False     # arctic: dense FFN parallel to MoE
    capacity_factor: float = 1.25

    # --- SSM / Mamba2 ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256             # SSD chunk length

    # --- hybrid (zamba2) ---
    attn_period: int = 0             # shared attention every N layers

    # --- enc-dec (whisper) ---
    num_encoder_layers: int = 0

    # --- vlm (llava) ---
    num_image_tokens: int = 0        # max anyres patch embeddings per request
    vision_dim: int = 0              # stub vision encoder output width

    # --- numerics ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True               # activation checkpointing per scan period
    scan_unroll: bool = False        # unroll layer/chunk scans (cost probes)

    # provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def ssm_ngroups(self) -> int:
        return 1

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    # ------------------------------------------------------------------
    def segments(self) -> Tuple[Segment, ...]:
        """Decompose the layer stack into scannable segments."""
        L = self.num_layers
        if self.family in ("dense", "vlm"):
            if self.local_global_ratio > 0:
                # gemma3: (ratio local, 1 global) repeating; trailing locals.
                period = (ATTN_LOCAL,) * self.local_global_ratio + (ATTN,)
                n_full, rem = divmod(L, len(period))
                segs = []
                if n_full:
                    segs.append(Segment(period, n_full))
                if rem:
                    segs.append(Segment((ATTN_LOCAL,) * rem, 1))
                return tuple(segs)
            return (Segment((ATTN,), L),)
        if self.family == "moe":
            return (Segment((MOE,), L),)
        if self.family == "ssm":
            return (Segment((MAMBA2,), L),)
        if self.family == "hybrid":
            p = self.attn_period
            period = (MAMBA2,) * (p - 1) + (ZAMBA_ATTN,)
            n_full, rem = divmod(L, p)
            segs = []
            if n_full:
                segs.append(Segment(period, n_full))
            if rem:
                segs.append(Segment((MAMBA2,) * rem, 1))
            return tuple(segs)
        if self.family == "audio":
            return (
                Segment((ENC,), self.num_encoder_layers),
                Segment((DEC,), self.num_layers),
            )
        raise ValueError(f"unknown family {self.family}")

    def attn_layer_count(self) -> int:
        n = 0
        for seg in self.segments():
            for b in seg.pattern:
                if b in (ATTN, ATTN_LOCAL, ZAMBA_ATTN, DEC):
                    n += seg.n_periods
        return n

    # ------------------------------------------------------------------
    def kv_cache_bytes(self, batch: int, seq: int) -> int:
        """Approximate KV/state cache footprint (for HBM accounting in the
        scheduler/estimator; the dry-run uses real memory_analysis)."""
        itemsize = jnp.dtype(self.dtype).itemsize
        total = 0
        for seg in self.segments():
            for b in seg.pattern:
                if b in (ATTN, MOE, ZAMBA_ATTN, DEC):
                    total += (seg.n_periods * 2 * batch * seq
                              * self.num_kv_heads * self.head_dim * itemsize)
                    if b == DEC:  # cross-attention KV (encoder length ~ seq)
                        total += (seg.n_periods * 2 * batch * seq
                                  * self.num_kv_heads * self.head_dim * itemsize)
                elif b == ATTN_LOCAL:
                    w = min(self.sliding_window or seq, seq)
                    total += (seg.n_periods * 2 * batch * w
                              * self.num_kv_heads * self.head_dim * itemsize)
                if b in (MAMBA2, ZAMBA_ATTN):
                    # ssm state + conv state, O(1) in seq
                    total += seg.n_periods * batch * (
                        self.ssm_nheads * self.ssm_headdim * self.ssm_state
                        + (self.ssm_conv - 1)
                        * (self.ssm_d_inner + 2 * self.ssm_ngroups * self.ssm_state)
                    ) * itemsize
        return total

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hq, hkv, dh = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * dh * (hq + 2 * hkv) + hq * dh * d
        mlp = 3 * d * ff
        per = {}
        per[ATTN] = attn + mlp
        per[ATTN_LOCAL] = attn + mlp
        per[DEC] = 2 * attn + mlp
        per[ENC] = attn + mlp
        eff = self.expert_d_ff
        per[MOE] = attn + self.num_experts * 3 * d * eff + d * self.num_experts
        if self.dense_residual:
            per[MOE] += mlp
        dimm = self.ssm_d_inner
        ssm_in = d * (2 * dimm + 2 * self.ssm_ngroups * self.ssm_state
                      + self.ssm_nheads)
        per[MAMBA2] = ssm_in + dimm * d + self.ssm_conv * (
            dimm + 2 * self.ssm_ngroups * self.ssm_state)
        per[ZAMBA_ATTN] = per[MAMBA2]  # shared attn counted once below
        total = 0
        for seg in self.segments():
            for b in seg.pattern:
                total += per[b] * seg.n_periods
        if self.family == "hybrid":
            total += attn + mlp  # the single shared attention block
        total += V * d  # embed
        total += V * d  # lm head (untied)
        if self.family == "vlm":
            total += self.vision_dim * d  # projector
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, eff = self.d_model, self.expert_d_ff
        inactive = (self.num_experts - self.top_k) * 3 * d * eff
        total = 0
        for seg in self.segments():
            total += seg.n_layers * inactive
        return self.param_count() - total
