"""GQA attention with KV-cache support for train / chunked-prefill / decode.

Cache layout per attention layer: ``{"k": [B, S, Hkv, Dh], "v": ...}``.
``S`` is the cache capacity — the full max sequence for global layers or
the sliding window for gemma3-style local layers (ring buffer).  Keys are
stored with RoPE already applied at their absolute position, so reads are
position-free.  Masks are computed analytically from the per-row write
position (no stored position arrays needed for sequential writes).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rms_norm, split_keys

# Opt-in Pallas kernel execution (interpret mode on CPU, native on TPU).
# Applies to the full-cache (non-windowed) chunked-prefill / decode
# attention paths; enable with `attention.use_kernels(True)` — parity
# with the jnp path is asserted in tests/test_kernel_integration.py.
_USE_KERNELS = False


def use_kernels(on: bool):
    global _USE_KERNELS
    _USE_KERNELS = on


def init_attention(key, cfg, *, rope: bool = True):
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = split_keys(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, hkv * dh), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, hkv * dh), cfg.param_dtype),
        "wo": dense_init(ks[3], (hq * dh, d), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), cfg.param_dtype)
        p["bk"] = jnp.zeros((hkv * dh,), cfg.param_dtype)
        p["bv"] = jnp.zeros((hkv * dh,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((dh,), cfg.param_dtype)
    return p


def _project_qkv(p, cfg, x):
    B, T, _ = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,de->bte", x, p["wq"])
    k = jnp.einsum("btd,de->bte", x, p["wk"])
    v = jnp.einsum("btd,de->bte", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, hq, dh)
    k = k.reshape(B, T, hkv, dh)
    v = v.reshape(B, T, hkv, dh)
    # pin kv-head-axis sharding: without this GSPMD may shard the
    # head_dim contraction (head counts rarely divide the model axis)
    # and emit partial-sum all-reduces of the full [B,H,T,S] scores
    from repro.distributed import hints
    k = hints.constrain_heads(k)
    v = hints.constrain_heads(v)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _gqa_scores(q, k):
    """q [B,T,Hq,D], k [B,S,Hkv,D] -> scores [B,Hkv,G,T,S]."""
    from repro.distributed import hints
    B, T, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(B, T, hkv, g, dh)
    if hints.active():
        import jax as _jax
        from jax.sharding import PartitionSpec as _P
        b = hints._state.batch if B > 1 else None
        qg = _jax.lax.with_sharding_constraint(
            qg, _P(b, None, hints._state.model, None, None))
        k = hints.constrain_heads(k)
    return jnp.einsum("btkgd,bskd->bkgts", qg, k) * (dh ** -0.5)


def _gqa_out(probs, v, wo):
    """probs [B,Hkv,G,T,S], v [B,S,Hkv,D] -> [B,T,d_model]."""
    B, hkv, g, T, S = probs.shape
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    out = out.reshape(B, T, hkv * g * v.shape[-1])
    return jnp.einsum("bte,ed->btd", out, wo)


def _masked_softmax(scores, mask):
    scores = scores.astype(jnp.float32)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (possible for padded ring slots) -> zero output
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    return probs


def causal_mask(q_pos, kv_pos, window: int = 0):
    """q_pos [B,T], kv_pos [B,S] absolute positions -> mask [B,1,1,T,S]."""
    m = kv_pos[:, None, :] <= q_pos[:, :, None]
    m &= kv_pos[:, None, :] >= 0
    if window:
        m &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    return m[:, None, None, :, :]


def ring_slot_positions(write_end, capacity: int):
    """Absolute position held by each ring-buffer slot after sequential
    writes ending at ``write_end`` (exclusive).  write_end: [B]."""
    j = jnp.arange(capacity)[None, :]
    last = write_end[:, None] - 1
    a = last - jnp.mod(last - j, capacity)
    return jnp.where((a >= 0) & (write_end[:, None] > 0), a, -1)


def quantize_int8(x):
    """Symmetric per-token int8 KV quantization: x [..., D] ->
    (q int8 [..., D], scale f32 [...]) with ``x ~= q * scale``.  The
    scale is amax/127 per (token, kv head); all-zero tokens (fresh pool
    slots, padding) get scale 1 so dequantization is exact zero."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def paged_write(cache_k, cache_v, k_new, v_new, positions, tables,
                block_size: int, valid_len=None):
    """Scatter [B,T] new KV into a physical block pool.

    cache_k/cache_v: [P, Hkv, D] flat-token pools (P = num_blocks * bs,
    block-major).  positions: [B,T] absolute positions; tables: [B,NB]
    int32 block tables (entry < 0 = unallocated).  The destination slot
    for (b, t) is ``tables[b, pos // bs] * bs + pos % bs``; invalid
    tokens (padding beyond ``valid_len``, unallocated blocks) are routed
    to the out-of-range slot P and dropped on-device.
    """
    B, T = k_new.shape[:2]
    P = cache_k.shape[0]
    NB = tables.shape[1]
    bi = positions // block_size
    blk = jnp.take_along_axis(tables, jnp.clip(bi, 0, NB - 1), axis=1)
    ok = (blk >= 0) & (bi < NB)
    if valid_len is not None:
        ok &= jnp.arange(T)[None, :] < valid_len[:, None]
    dest = jnp.where(ok, blk * block_size + positions % block_size, P)
    flat = dest.reshape(-1)
    cache_k = cache_k.at[flat].set(
        k_new.reshape((B * T,) + k_new.shape[2:]), mode="drop")
    cache_v = cache_v.at[flat].set(
        v_new.reshape((B * T,) + v_new.shape[2:]), mode="drop")
    return cache_k, cache_v


def paged_gather(pool, tables, block_size: int):
    """Gather a per-row dense KV view [B, NB*bs, Hkv, D] from the block
    pool, plus logical kv positions [B, NB*bs] (-1 for unallocated
    blocks).  This is the jnp reference read path — the Pallas kernels
    dereference the table inside the kernel instead of materializing the
    view."""
    B, NB = tables.shape
    idx = (jnp.maximum(tables, 0)[:, :, None] * block_size
           + jnp.arange(block_size, dtype=jnp.int32)[None, None, :])
    dense = pool[idx.reshape(B, NB * block_size)]
    kv_pos = jnp.where(
        jnp.repeat(tables >= 0, block_size, axis=1),
        jnp.arange(NB * block_size, dtype=jnp.int32)[None, :], -1)
    return dense, kv_pos


def write_cache(cache_k, cache_v, k_new, v_new, start, valid_len=None):
    """Write [B,T] new KV at absolute positions start..start+T (per row).

    For ring buffers (capacity < max_seq) the slot is pos % capacity.
    start: [B] int32.  Assumes T <= capacity.

    valid_len: optional [B] int32 — rows padded to a common T bucket only
    write their first ``valid_len`` tokens; padding writes are routed to
    an out-of-range slot and dropped on-device (no host round-trip, no
    garbage keys in the cache).
    """
    B, T = k_new.shape[:2]
    S = cache_k.shape[1]
    pos = start[:, None] + jnp.arange(T)[None, :]
    slots = jnp.mod(pos, S)
    mode = None
    if valid_len is not None:
        token_valid = jnp.arange(T)[None, :] < valid_len[:, None]
        slots = jnp.where(token_valid, slots, S)
        mode = "drop"
    bidx = jnp.arange(B)[:, None].repeat(T, 1)
    cache_k = cache_k.at[bidx, slots].set(k_new, mode=mode)
    cache_v = cache_v.at[bidx, slots].set(v_new, mode=mode)
    return cache_k, cache_v


def self_attention(p, cfg, x, positions, cache=None, *, window: int = 0,
                   rope: bool = True, valid_len=None, block_tables=None):
    """positions: [B,T] absolute positions of x's tokens.

    cache=None  -> pure in-chunk causal attention (training / encoder-free).
    cache={k,v} -> write chunk into cache, attend over full cache (chunked
                   prefill when T>1, decode when T==1).
    valid_len   -> optional [B] per-row valid token counts for T-padded
                   batched prefill (full-cache layers only): padding KV
                   writes are dropped, padded queries are masked off by
                   causality (their outputs are discarded by the caller).
    block_tables -> optional ``(tables [B,NB] int32, block_size)``: the
                   cache is a PAGED pool ({k,v}: [P, Hkv, D] flat-token
                   block pools) and each row's KV is addressed through
                   its block table.  Full (non-windowed) attention only.
    Returns (out [B,T,d], new_cache).
    """
    B, T, _ = x.shape
    if valid_len is not None and (cache is None or window):
        raise NotImplementedError(
            "valid_len packing requires a full (non-windowed) KV cache")
    if block_tables is not None and (cache is None or window):
        raise NotImplementedError(
            "paged KV requires a full (non-windowed) cache")
    q, k, v = _project_qkv(p, cfg, x)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if block_tables is not None:
        return _paged_attention(p, cfg, x, q, k, v, positions, cache,
                                block_tables, valid_len)
    if cache is None:
        mask = causal_mask(positions, positions, window)
        probs = _masked_softmax(_gqa_scores(q, k), mask)
        return _gqa_out(probs.astype(x.dtype), v, p["wo"]), None
    S = cache["k"].shape[1]
    start = positions[:, 0]
    if window:
        # Ring buffer: writing first would overwrite keys still needed by
        # early queries in this chunk.  Attend over (prior cache + fresh
        # chunk keys), then write the chunk (its last S tokens if T >= S).
        prior_pos = ring_slot_positions(start, S)
        k_all = jnp.concatenate([cache["k"], k], axis=1)
        v_all = jnp.concatenate([cache["v"], v], axis=1)
        kv_pos = jnp.concatenate([prior_pos, positions], axis=1)
        mask = causal_mask(positions, kv_pos, window)
        probs = _masked_softmax(_gqa_scores(q, k_all), mask)
        out = _gqa_out(probs.astype(x.dtype), v_all, p["wo"])
        if T >= S:
            k, v = k[:, -S:], v[:, -S:]
            start = positions[:, -1] + 1 - S
        ck, cv = write_cache(cache["k"], cache["v"], k, v, start)
        return out, {"k": ck, "v": cv}
    ck, cv = write_cache(cache["k"], cache["v"], k, v, start, valid_len)
    if _USE_KERNELS and (valid_len is None or T == 1):
        if T == 1:
            from repro.kernels.decode_attention.ops import decode_attention
            o = decode_attention(q[:, 0], ck, cv,
                                 (positions[:, -1] + 1).astype(jnp.int32))
            o = o[:, None]
        else:
            # kernel takes a scalar chunk offset: rows are uniform within
            # a prefill chunk call (the engine prefills row-wise)
            from repro.kernels.chunked_prefill_attention.ops import (
                chunked_prefill_attention)
            o = chunked_prefill_attention(q, ck, cv, positions[0, 0])
        out = jnp.einsum("bte,ed->btd",
                         o.reshape(B, T, -1).astype(x.dtype), p["wo"])
        return out, {"k": ck, "v": cv}
    write_end = positions[:, -1] + 1
    kv_pos = ring_slot_positions(write_end, S)
    mask = causal_mask(positions, kv_pos, window)
    probs = _masked_softmax(_gqa_scores(q, ck), mask)
    out = _gqa_out(probs.astype(x.dtype), cv, p["wo"])
    return out, {"k": ck, "v": cv}


def _paged_attention(p, cfg, x, q, k, v, positions, cache, block_tables,
                     valid_len):
    """Write the chunk into the block pool, attend over the row's
    table-resident KV.  The same call handles chunked prefill (T > 1)
    and decode (valid == 1 rows of a mixed batch, or T == 1): masks
    derive from absolute positions, exactly as the dense path."""
    B, T, _ = x.shape
    tables, bs = block_tables
    quant = "k_scale" in cache
    if quant:
        # int8 tier: quantize the chunk once at write time; scales live
        # in sibling [P, Hkv] pools addressed by the same destinations
        k, ks = quantize_int8(k)
        v, vs = quantize_int8(v)
        cks, cvs = paged_write(cache["k_scale"], cache["v_scale"], ks, vs,
                               positions, tables, bs, valid_len)
    ck, cv = paged_write(cache["k"], cache["v"], k, v, positions, tables,
                         bs, valid_len)
    new_cache = ({"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
                 if quant else {"k": ck, "v": cv})
    if _USE_KERNELS:
        scales = dict(k_scale=cks, v_scale=cvs) if quant else {}
        if T == 1:
            from repro.kernels.decode_attention.ops import (
                paged_decode_attention)
            o = paged_decode_attention(
                q[:, 0], ck, cv, tables,
                (positions[:, -1] + 1).astype(jnp.int32), block_size=bs,
                **scales)
            o = o[:, None]
        else:
            from repro.kernels.chunked_prefill_attention.ops import (
                paged_chunked_prefill_attention)
            valid = (valid_len if valid_len is not None
                     else jnp.full((B,), T, jnp.int32))
            o = paged_chunked_prefill_attention(
                q, ck, cv, tables, positions[:, 0].astype(jnp.int32),
                valid.astype(jnp.int32), block_size=bs, **scales)
        out = jnp.einsum("bte,ed->btd",
                         o.reshape(B, T, -1).astype(x.dtype), p["wo"])
        return out, new_cache
    kd, kv_pos = paged_gather(ck, tables, bs)
    vd, _ = paged_gather(cv, tables, bs)
    if quant:
        ksd, _ = paged_gather(cks, tables, bs)
        vsd, _ = paged_gather(cvs, tables, bs)
        kd = (kd.astype(jnp.float32) * ksd[..., None]).astype(q.dtype)
        vd = (vd.astype(jnp.float32) * vsd[..., None]).astype(q.dtype)
    mask = causal_mask(positions, kv_pos)
    probs = _masked_softmax(_gqa_scores(q, kd), mask)
    out = _gqa_out(probs.astype(x.dtype), vd, p["wo"])
    return out, new_cache


def init_cross_attention(key, cfg):
    return init_attention(key, cfg, rope=False)


def cross_attention(p, cfg, x, kv, kv_valid=None):
    """x [B,T,d] attends over precomputed cross KV {k,v} [B,S,Hkv,D]."""
    q, _, _ = _project_qkv(p, cfg, x)
    S = kv["k"].shape[1]
    scores = _gqa_scores(q, kv["k"])
    if kv_valid is None:
        mask = jnp.ones(scores.shape[-2:], bool)[None, None, None]
    else:
        mask = kv_valid[:, None, None, None, :]
    probs = _masked_softmax(scores, mask)
    return _gqa_out(probs.astype(x.dtype), kv["v"], p["wo"])


def project_cross_kv(p, cfg, enc_out):
    """Compute cross-attention KV from encoder output once (prefill)."""
    B, S, _ = enc_out.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"])
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return {"k": k.reshape(B, S, hkv, dh), "v": v.reshape(B, S, hkv, dh)}
