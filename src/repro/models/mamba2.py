"""Mamba2 (SSD — state-space duality) mixer layer in pure JAX.

Implements the chunked SSD algorithm (arXiv:2405.21060) with support for
an *initial state*, which is what makes chunked prefill and prefill→decode
state handoff (the paper's "flowing" migration for SSM archs) exact.

Cache per layer: ``{"conv": [B, k-1, C_in], "ssm": [B, H, P, N]}`` —
O(1) in sequence length.  The same ``ssd_chunked`` function is the oracle
(`ref.py`) for the Pallas ``ssd_scan`` kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm, split_keys


def conv_channels(cfg) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_mamba2(key, cfg):
    d = cfg.d_model
    dinner = cfg.ssm_d_inner
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    G = cfg.ssm_ngroups
    cin = conv_channels(cfg)
    ks = split_keys(key, 4)
    proj_out = 2 * dinner + 2 * G * N + H
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), cfg.param_dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, cin), cfg.param_dtype,
                             scale=cfg.ssm_conv ** -0.5),
        "conv_b": jnp.zeros((cin,), cfg.param_dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.zeros((dinner,), cfg.param_dtype),
        "out_proj": dense_init(ks[2], (dinner, d), cfg.param_dtype),
    }


# ---------------------------------------------------------------------------
# SSD core (also the kernel oracle)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """Chunked SSD scan.

    x:  [b, t, h, p]    per-head inputs
    dt: [b, t, h]       post-softplus step sizes
    A:  [h]             negative real decay
    B:  [b, t, g, n]    input projections  (g groups broadcast over heads)
    C:  [b, t, g, n]    output projections
    init_state: [b, h, p, n] or None
    Returns (y [b,t,h,p], final_state [b,h,p,n]).  Requires t % chunk == 0.
    """
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    rep = h // g

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bf, rep, axis=3)          # [b,nc,l,h,n]
    Ch = jnp.repeat(Cf, rep, axis=3)

    dA = dtf * A[None, None, None, :]         # [b,nc,l,h]
    cum = jnp.cumsum(dA, axis=2)              # inclusive cumsum within chunk
    seg_sum = cum[:, :, -1]                   # [b,nc,h] total decay per chunk

    # --- intra-chunk (quadratic within chunk) ---
    # decay from j to i (i>=j): exp(cum_i - cum_j)
    li = cum[:, :, :, None, :]                # [b,nc,i,1,h]
    lj = cum[:, :, None, :, :]                # [b,nc,1,j,h]
    iidx = jnp.arange(chunk)
    causal = (iidx[:, None] >= iidx[None, :])[None, None, :, :, None]
    # mask INSIDE the exponent: anti-causal entries have li - lj > 0 and
    # exp would overflow to inf, poisoning gradients through the where
    decay = jnp.exp(jnp.where(causal, li - lj, -1e30))
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)
    xdt = xf * dtf[..., None]
    y = jnp.einsum("bcijh,bcjhp->bcihp", cb * decay, xdt)

    # --- chunk-final states ---
    # state contribution of chunk c: sum_j exp(seg_sum - cum_j) B_j (x_j dt_j)
    sdecay = jnp.exp(seg_sum[:, :, None, :] - cum)            # [b,nc,l,h]
    chunk_states = jnp.einsum("bclhn,bclhp,bclh->bchpn", Bh, xdt, sdecay)

    # --- inter-chunk recurrence over nc (sequential scan) ---
    if init_state is None:
        s0 = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        s0 = init_state.astype(jnp.float32)

    def step(carry, inp):
        cs, seg = inp                          # [b,h,p,n], [b,h]
        prev = carry
        new = prev * jnp.exp(seg)[:, :, None, None] + cs
        return new, prev                       # emit state *entering* chunk

    cs_t = jnp.moveaxis(chunk_states, 1, 0)    # [nc,b,h,p,n]
    seg_t = jnp.moveaxis(seg_sum, 1, 0)        # [nc,b,h]
    final, entering = jax.lax.scan(step, s0, (cs_t, seg_t))
    entering = jnp.moveaxis(entering, 0, 1)    # [b,nc,h,p,n]

    # --- inter-chunk output: C_i exp(cum_i) S_entering ---
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp", Ch, entering,
                         jnp.exp(cum))
    y = (y + y_inter).reshape(b, t, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token recurrence.  x [b,h,p], dt [b,h], B/C [b,g,n],
    state [b,h,p,n] -> (y [b,h,p], new_state)."""
    g = B.shape[1]
    rep = x.shape[1] // g
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])                        # [b,h]
    upd = jnp.einsum("bhp,bhn,bh->bhpn", xf, Bh, dtf)
    new_state = state.astype(jnp.float32) * dA[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv with state
# ---------------------------------------------------------------------------

def _causal_conv(xBC, w, b, conv_state):
    """xBC [B,T,Cin]; w [k,Cin]; conv_state [B,k-1,Cin] or None."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], k - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)            # [B, T+k-1, Cin]
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(k))
    new_state = full[:, -(k - 1):] if k > 1 else None
    return out + b, new_state


# ---------------------------------------------------------------------------
# Full mixer layer
# ---------------------------------------------------------------------------

def mamba2_block(p, cfg, x, cache=None, *, ssd_fn=None):
    """x [B,T,d].  cache None -> fresh sequence (train / full prefill,
    states discarded unless needed).  cache given -> chunked prefill or
    decode continuation; returns updated cache.

    ssd_fn: optional override of the chunked SSD implementation (used to
    swap in the Pallas kernel).
    """
    B, T, d = x.shape
    dinner = cfg.ssm_d_inner
    H, P, N, G = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    ssd = ssd_fn or ssd_chunked
    if ssd_fn is None:
        from repro.models import attention as _attn
        if _attn._USE_KERNELS:
            from repro.kernels.ssd_scan.ops import ssd_scan as ssd

    zxbcdt = jnp.einsum("btd,de->bte", x, p["in_proj"])
    z, xBC, dt_raw = jnp.split(
        zxbcdt, [dinner, dinner + conv_channels(cfg)], axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, Bmat, Cmat = jnp.split(xBC, [dinner, dinner + G * N], axis=-1)
    xs = xs.reshape(B, T, H, P)
    Bmat = Bmat.reshape(B, T, G, N)
    Cmat = Cmat.reshape(B, T, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    init_state = cache["ssm"] if cache is not None else None
    if T == 1:
        st = init_state if init_state is not None else jnp.zeros(
            (B, H, P, N), jnp.float32)
        y, new_state = ssd_decode_step(
            xs[:, 0], dt[:, 0], A, Bmat[:, 0], Cmat[:, 0], st)
        y = y[:, None]
    else:
        chunk = min(cfg.ssm_chunk, T)
        while T % chunk != 0:
            chunk //= 2
        y, new_state = ssd(xs, dt.astype(x.dtype), A, Bmat, Cmat,
                           chunk, init_state)
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, T, dinner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_state}
    return out, new_cache


def init_mamba2_cache(cfg, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_channels(cfg)), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                          cfg.ssm_state), jnp.float32),
    }
