"""Shared pure-JAX building blocks: init helpers, RMSNorm, RoPE, SwiGLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LLaMA-style)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., T, H, D]; positions: broadcastable to [..., T] (absolute)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, D/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., T, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = split_keys(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy over valid positions. logits [..., V] labels [...]"""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
