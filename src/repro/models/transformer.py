"""Unified decoder stack assembling all block types, with scan-over-periods.

Entry points (all functional, pure JAX):

  init_params(key, cfg)                     -> params pytree
  init_cache(cfg, batch, max_seq, dtype)    -> cache pytree (None entries for
                                               cache-free blocks)
  forward(params, cfg, tokens, positions, cache, ...) -> (logits, cache, aux)

Modes:
  train / full-context:  cache=None, T = full sequence, causal in-chunk.
  chunked prefill:       cache given, T = chunk size, writes KV at positions.
  decode:                cache given, T = 1.

The layer stack is organised as ``cfg.segments()``: each segment scans
over ``n_periods`` repetitions of a block pattern, with per-period params
and caches as scan xs/ys.  This keeps HLO size independent of depth.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import config as cfg_lib
from repro.models import mamba2 as m2
from repro.models import moe as moe_lib
from repro.models.common import dense_init, init_mlp, mlp, rms_norm, split_keys
from repro.models.config import (ATTN, ATTN_LOCAL, DEC, ENC, MAMBA2, MOE,
                                 ZAMBA_ATTN, ModelConfig, Segment)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, btype: str):
    d = cfg.d_model
    ks = split_keys(key, 6)
    zeros = lambda: jnp.zeros((d,), cfg.param_dtype)
    if btype in (ATTN, ATTN_LOCAL, ENC):
        return {"ln1": zeros(), "attn": attn_lib.init_attention(ks[0], cfg),
                "ln2": zeros(), "mlp": init_mlp(ks[1], d, cfg.d_ff,
                                                cfg.param_dtype)}
    if btype == MOE:
        p = {"ln1": zeros(), "attn": attn_lib.init_attention(ks[0], cfg),
             "ln2": zeros(), "moe": moe_lib.init_moe(ks[1], cfg)}
        if cfg.dense_residual:
            p["dense_mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.param_dtype)
        return p
    if btype in (MAMBA2, ZAMBA_ATTN):
        p = {"ln1": zeros(), "mixer": m2.init_mamba2(ks[0], cfg)}
        if cfg.d_ff > 0:
            p["ln2"] = zeros()
            p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.param_dtype)
        if btype == ZAMBA_ATTN:
            p["ln_attn"] = zeros()
        return p
    if btype == DEC:
        return {"ln1": zeros(), "attn": attn_lib.init_attention(ks[0], cfg),
                "ln_x": zeros(),
                "xattn": attn_lib.init_cross_attention(ks[1], cfg),
                "ln2": zeros(), "mlp": init_mlp(ks[2], d, cfg.d_ff,
                                                cfg.param_dtype)}
    raise ValueError(btype)


def init_params(key, cfg: ModelConfig):
    ks = split_keys(key, 8 + len(cfg.segments()))
    d, V = cfg.d_model, cfg.vocab_size
    params = {
        "embed": dense_init(ks[0], (V, d), cfg.param_dtype, scale=1.0),
        "lm_head": dense_init(ks[1], (d, V), cfg.param_dtype),
        "final_norm": jnp.zeros((d,), cfg.param_dtype),
    }
    if cfg.family == "hybrid":
        params["shared_attn"] = attn_lib.init_attention(ks[2], cfg)
    if cfg.family == "vlm":
        params["projector"] = dense_init(ks[3], (cfg.vision_dim, d),
                                         cfg.param_dtype)
    segs = []
    for si, seg in enumerate(cfg.segments()):
        kseg = ks[8 + si]
        pos_params = []
        for pi, btype in enumerate(seg.pattern):
            kpos = jax.random.fold_in(kseg, pi)
            stacked = jax.vmap(
                lambda k: _init_block(k, cfg, btype)
            )(jax.random.split(kpos, seg.n_periods))
            pos_params.append(stacked)
        segs.append(tuple(pos_params))
    params["segments"] = tuple(segs)
    return params


def abstract_params(cfg: ModelConfig):
    """Parameter shapes without allocation (for the dry-run)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, btype: str, batch: int, max_seq: int,
                 cross_len: int, dtype):
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    kv = lambda s: {"k": jnp.zeros((batch, s, hkv, dh), dtype),
                    "v": jnp.zeros((batch, s, hkv, dh), dtype)}
    if btype == ATTN:
        return kv(max_seq)
    if btype == ATTN_LOCAL:
        return kv(min(cfg.sliding_window or max_seq, max_seq))
    if btype == MOE:
        return kv(max_seq)
    if btype == MAMBA2:
        return m2.init_mamba2_cache(cfg, batch, dtype)
    if btype == ZAMBA_ATTN:
        c = m2.init_mamba2_cache(cfg, batch, dtype)
        c.update(kv(max_seq))
        return c
    if btype == DEC:
        c = kv(max_seq)
        c["ck"] = jnp.zeros((batch, cross_len, hkv, dh), dtype)
        c["cv"] = jnp.zeros((batch, cross_len, hkv, dh), dtype)
        return c
    if btype == ENC:
        return None
    raise ValueError(btype)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None,
               cross_len: int = 0):
    dtype = dtype or cfg.param_dtype
    segs = []
    for seg in cfg.segments():
        pos_caches = []
        for btype in seg.pattern:
            c = _block_cache(cfg, btype, batch, max_seq, cross_len, dtype)
            if c is not None:
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (seg.n_periods,) + a.shape), c)
            pos_caches.append(c)
        segs.append(tuple(pos_caches))
    return {"segments": tuple(segs)}


def abstract_cache(cfg, batch, max_seq, dtype=None, cross_len: int = 0):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_seq, dtype, cross_len))


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=None, quant: Optional[str] = None):
    """Physical block-pool KV cache: every attention layer's KV lives in
    one shared pool of ``num_blocks`` fixed-size token blocks instead of
    per-slot [B, max_seq] rows.  Leaves are [n_periods, P, Hkv, Dh] with
    P = num_blocks * block_size (flat token axis, block-major); rows
    address it through int32 block tables passed to ``forward``.

    ``quant="int8"`` stores KV as symmetric per-token int8 with float32
    scales in sibling ``k_scale``/``v_scale`` pools [n_periods, P, Hkv]
    — roughly ``itemsize*Dh / (Dh + 4)`` x more resident tokens per HBM
    byte; the attention read path dequantizes (jnp reference) or the
    paged Pallas kernels fold the scale in per DMA'd block.

    Only full-cache global attention pages cleanly (ring-buffer windows
    and recurrent state have no per-token block identity), so every
    block type must be ATTN — the same gate as T-padded packing.
    """
    if quant not in (None, "int8"):
        raise ValueError(f"unsupported KV quantization {quant!r}")
    dtype = jnp.int8 if quant == "int8" else (dtype or cfg.param_dtype)
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    P = num_blocks * block_size
    segs = []
    for seg in cfg.segments():
        pos_caches = []
        for btype in seg.pattern:
            if btype != ATTN:
                raise ValueError(
                    f"paged KV cache requires all-ATTN segments, got {btype}")
            kv = {"k": jnp.zeros((seg.n_periods, P, hkv, dh), dtype),
                  "v": jnp.zeros((seg.n_periods, P, hkv, dh), dtype)}
            if quant == "int8":
                kv["k_scale"] = jnp.zeros((seg.n_periods, P, hkv),
                                          jnp.float32)
                kv["v_scale"] = jnp.zeros((seg.n_periods, P, hkv),
                                          jnp.float32)
            pos_caches.append(kv)
        segs.append(tuple(pos_caches))
    return {"segments": tuple(segs)}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _apply_block(btype, bp, cfg, x, positions, cache, shared_attn, enc_out,
                 valid_len=None, block_tables=None):
    """Returns (x, new_cache, aux_loss)."""
    from repro.distributed import hints
    x = hints.constrain_tokens(x)
    aux = jnp.zeros((), jnp.float32)
    if btype in (ATTN, ATTN_LOCAL, ENC):
        window = cfg.sliding_window if btype == ATTN_LOCAL else 0
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        if btype == ENC:
            # bidirectional: no mask beyond validity
            q, k, v = attn_lib._project_qkv(bp["attn"], cfg, h)
            q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
            k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
            scores = attn_lib._gqa_scores(q, k)
            probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
            a = attn_lib._gqa_out(probs.astype(x.dtype), v, bp["attn"]["wo"])
            nc = None
        else:
            a, nc = attn_lib.self_attention(bp["attn"], cfg, h, positions,
                                            cache, window=window,
                                            valid_len=valid_len,
                                            block_tables=block_tables)
        x = x + a
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + mlp(bp["mlp"], h)
        return x, nc, aux
    if btype == MOE:
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        a, nc = attn_lib.self_attention(bp["attn"], cfg, h, positions, cache,
                                        valid_len=valid_len)
        x = x + a
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        mo, aux = moe_lib.moe_ffn(bp["moe"], cfg, h)
        if cfg.dense_residual:
            mo = mo + mlp(bp["dense_mlp"], h)
        x = x + mo
        return x, nc, aux
    if btype in (MAMBA2, ZAMBA_ATTN):
        new_cache = dict(cache) if cache is not None else None
        if btype == ZAMBA_ATTN:
            h = rms_norm(x, bp["ln_attn"], cfg.norm_eps)
            kvc = ({"k": cache["k"], "v": cache["v"]}
                   if cache is not None else None)
            a, nkv = attn_lib.self_attention(shared_attn, cfg, h, positions,
                                             kvc)
            x = x + a
            if new_cache is not None:
                new_cache.update(nkv)
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        mcache = ({"conv": cache["conv"], "ssm": cache["ssm"]}
                  if cache is not None else None)
        mo, nmc = m2.mamba2_block(bp["mixer"], cfg, h, mcache)
        x = x + mo
        if new_cache is not None:
            new_cache.update(nmc)
        if cfg.d_ff > 0 and "mlp" in bp:
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + mlp(bp["mlp"], h)
        return x, new_cache, aux
    if btype == DEC:
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        kvc = ({"k": cache["k"], "v": cache["v"]}
               if cache is not None else None)
        a, nkv = attn_lib.self_attention(bp["attn"], cfg, h, positions, kvc)
        x = x + a
        h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
        if enc_out is not None:
            ckv = attn_lib.project_cross_kv(bp["xattn"], cfg, enc_out)
        else:
            ckv = {"k": cache["ck"], "v": cache["cv"]}
        x = x + attn_lib.cross_attention(bp["xattn"], cfg, h, ckv)
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + mlp(bp["mlp"], h)
        new_cache = None
        if cache is not None:
            new_cache = dict(nkv)
            new_cache["ck"] = ckv["k"].astype(cache["ck"].dtype)
            new_cache["cv"] = ckv["v"].astype(cache["cv"].dtype)
        return x, new_cache, aux
    raise ValueError(btype)


def _run_segment(seg: Segment, seg_params, cfg, x, positions, seg_cache,
                 shared_attn, enc_out, use_remat: bool, valid_len=None,
                 block_tables=None):
    """Scan over the segment's periods."""

    cache_present = tuple(
        seg_cache is not None and seg_cache[i] is not None
        for i in range(len(seg.pattern)))
    has_cache = any(cache_present)

    # The cache rides in the scan CARRY and is updated in place with
    # dynamic_update_slice at the current period index: XLA aliases
    # while-loop state, so only ONE copy of the stacked cache is live.
    # (Threading it as xs -> ys keeps input and output stacks alive
    # simultaneously — measured as a full extra cache copy per segment.)
    def body(carry, xs):
        x, aux, cache_stack = carry
        p_params, idx = xs
        new_stack = []
        for i, btype in enumerate(seg.pattern):
            if cache_present[i]:
                c = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, idx, 0, keepdims=False), cache_stack[i])
            else:
                c = None
            x, nc, block_aux = _apply_block(btype, p_params[i], cfg, x,
                                            positions, c, shared_attn,
                                            enc_out, valid_len,
                                            block_tables)
            aux = aux + block_aux
            if cache_present[i]:
                new_stack.append(jax.tree.map(
                    lambda a, n: jax.lax.dynamic_update_index_in_dim(
                        a, n.astype(a.dtype), idx, 0),
                    cache_stack[i], nc))
            else:
                new_stack.append(cache_stack[i])
        return (x, aux, tuple(new_stack)), ()

    if use_remat:
        body = jax.checkpoint(body)

    carry_cache = tuple(
        c if cache_present[i] else 0
        for i, c in enumerate(seg_cache if seg_cache is not None
                              else [None] * len(seg.pattern)))
    idxs = jnp.arange(seg.n_periods, dtype=jnp.int32)
    (x, aux, carry_cache), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), carry_cache),
        (seg_params, idxs), unroll=cfg.scan_unroll)
    new_caches = None
    if has_cache:
        new_caches = tuple(
            c if cache_present[i] else None
            for i, c in enumerate(carry_cache))
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, positions=None, cache=None, *,
            image_embeds=None, audio_embeds=None, compute_logits=True,
            valid_len=None, block_tables=None):
    """tokens: [B, T] int32.  positions: [B, T] absolute positions (defaults
    to arange).  cache: from init_cache, or None for train/full-context.

    image_embeds: [B, S_img, vision_dim] (vlm prefill) — prepended.
    audio_embeds: [B, S_frames, d_model] (audio prefill) — encoder input.
    valid_len: [B] int32 per-row valid token counts for T-padded batched
    prefill (full-cache attention families only); padding KV writes are
    dropped so the cache stays exactly sequential.
    block_tables: optional ``(tables [B, NB] int32, block_size)`` — the
    cache is a paged block pool from ``init_paged_cache`` and every row
    addresses its KV through its block table (all-ATTN configs only).

    Returns (logits [B, T', V] or hidden, new_cache, aux_loss).
    """
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    if image_embeds is not None:
        img = jnp.einsum("bsv,vd->bsd",
                         image_embeds.astype(cfg.param_dtype),
                         params["projector"])
        x = jnp.concatenate([img, x.astype(img.dtype)], axis=1)
    T = x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                     (B, T))
    shared_attn = params.get("shared_attn")
    use_remat = cfg.remat and cache is None

    segments = cfg.segments()
    new_seg_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    enc_out = None

    for si, seg in enumerate(segments):
        seg_params = params["segments"][si]
        seg_cache = cache["segments"][si] if cache is not None else None
        if seg.pattern == (cfg_lib.ENC,):
            # encoder path: runs over audio embeddings, not x
            if audio_embeds is None:
                new_seg_caches.append(seg_cache)
                continue
            a = audio_embeds.astype(cfg.param_dtype)
            apos = jnp.broadcast_to(
                jnp.arange(a.shape[1], dtype=jnp.int32)[None],
                (B, a.shape[1]))
            enc_out, _, _ = _run_segment(seg, seg_params, cfg, a, apos, None,
                                         shared_attn, None, use_remat)
            new_seg_caches.append(seg_cache)
            continue
        x, ncache, aux = _run_segment(seg, seg_params, cfg, x, positions,
                                      seg_cache, shared_attn, enc_out,
                                      use_remat, valid_len, block_tables)
        aux_total = aux_total + aux
        new_seg_caches.append(ncache)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out = x
    if compute_logits:
        out = jnp.einsum("btd,dv->btv", x, params["lm_head"])
    new_cache = None
    if cache is not None:
        new_cache = {"segments": tuple(new_seg_caches)}
    return out, new_cache, aux_total


# ---------------------------------------------------------------------------
# Convenience entry points used by engine / launchers
# ---------------------------------------------------------------------------

def train_loss(params, cfg: ModelConfig, batch):
    """batch: {tokens [B,T], labels [B,T], (optional) image_embeds,
    audio_embeds, loss_mask}."""
    logits, _, aux = forward(
        params, cfg, batch["tokens"],
        image_embeds=batch.get("image_embeds"),
        audio_embeds=batch.get("audio_embeds"))
    labels = batch["labels"]
    # vlm: logits cover [img ; text]; score only the text tail
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]
    from repro.models.common import softmax_xent
    loss = softmax_xent(logits[:, :-1], labels[:, 1:],
                        batch.get("loss_mask", None))
    return loss + 0.01 * aux


def prefill(params, cfg, tokens, cache, start_pos, **kw):
    """Chunked prefill: write tokens at start_pos.., return last logits."""
    B, T = tokens.shape
    if kw.get("image_embeds") is not None:
        T += kw["image_embeds"].shape[1]  # image tokens are prepended
    positions = start_pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
    logits, cache, _ = forward(params, cfg, tokens, positions, cache, **kw)
    return logits[:, -1], cache


def decode_step(params, cfg, tokens, cache, pos, **kw):
    """tokens [B,1]; pos [B] absolute position of the new token."""
    logits, cache, _ = forward(params, cfg, tokens, pos[:, None], cache, **kw)
    return logits[:, -1], cache


def full_prefill(params, cfg, tokens, cache, chunk_size: int, *,
                 image_embeds=None, audio_embeds=None):
    """Prefill a full prompt as a scan over chunked-prefill steps —
    exactly what a production instance executes, with memory bounded by
    one chunk's attention scores instead of O(S^2).

    The first chunk carries the modality embeddings (VLM patches compute
    alongside it; the audio encoder runs once and populates the cross-KV
    cache).  Requires (text) S % chunk_size == 0.

    Returns (last_logits [B, V], cache).
    """
    B, S = tokens.shape
    assert S % chunk_size == 0, (S, chunk_size)
    n_chunks = S // chunk_size

    # chunk 0 carries image/audio embeds
    first = tokens[:, :chunk_size]
    start0 = jnp.zeros((B,), jnp.int32)
    last, cache = prefill(params, cfg, first, cache, start0,
                          image_embeds=image_embeds,
                          audio_embeds=audio_embeds)
    if n_chunks == 1:
        return last, cache
    offset = chunk_size + (image_embeds.shape[1]
                           if image_embeds is not None else 0)
    rest = tokens[:, chunk_size:].reshape(B, n_chunks - 1, chunk_size)
    rest = jnp.moveaxis(rest, 1, 0)                  # [n-1, B, C]

    def body(cache, inp):
        i, chunk = inp
        start = jnp.full((B,), offset, jnp.int32) + i * chunk_size
        lg, cache = prefill(params, cfg, chunk, cache, start)
        return cache, lg

    idx = jnp.arange(n_chunks - 1, dtype=jnp.int32)
    cache, lgs = jax.lax.scan(body, cache, (idx, rest),
                              unroll=cfg.scan_unroll)
    return lgs[-1], cache
