"""Mixture-of-experts FFN with capacity-based expert-parallel dispatch.

Two dispatch strategies, both FLOP-faithful to *active* parameters:

* per-row dispatch (prefill / training, T large): tokens of each batch
  row are dispatched independently — position-in-expert cumsums run over
  the sequence axis only, so the token axis shards cleanly over the
  ``data`` mesh axis with no cross-device cumsum.  Grouped activations
  ``[B, E, C, d]`` shard E over ``model`` (expert parallelism).
* global dispatch (decode, T == 1): tokens are flattened across the
  batch; capacity C = ceil(B·k/E·cf) keeps the expert einsum at
  ~active-FLOPs instead of dense all-expert compute.

Capacity overflow drops tokens (standard "dropping" MoE); dropped tokens
fall through to the residual connection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys


def init_moe(key, cfg):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), cfg.param_dtype),
        "w_up": dense_init(ks[2], (E, d, f), cfg.param_dtype),
        "w_down": dense_init(ks[3], (E, f, d), cfg.param_dtype),
    }


def _route(p, cfg, x):
    """x [..., d] -> (weights [..., k], idx [..., k], aux_loss scalar)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    k = cfg.top_k
    vals, idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(vals, axis=-1)
    # load-balance auxiliary loss (Switch-style)
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs.reshape(-1, cfg.num_experts), axis=0)
    onehot = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(onehot, axis=-2).reshape(-1, cfg.num_experts),
                  axis=0) / k
    aux = cfg.num_experts * jnp.sum(me * ce)
    return weights, idx, aux


def _expert_ffn(p, xg):
    """xg [..., E, C, d] -> [..., E, C, d] via per-expert SwiGLU."""
    g = jnp.einsum("...ecd,edf->...ecf", xg, p["w_gate"])
    u = jnp.einsum("...ecd,edf->...ecf", xg, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"])


def _dispatch_combine(p, cfg, xt, weights, idx, capacity: int):
    """Dispatch tokens xt [N, d] with routing (weights/idx [N, k]) into
    grouped [E, C, d], run experts, combine back to [N, d]."""
    N, d = xt.shape
    E, k = cfg.num_experts, cfg.top_k
    fe = idx.reshape(N * k)                             # expert of each slot
    fw = weights.reshape(N * k)
    tok = jnp.repeat(jnp.arange(N), k)
    onehot = jax.nn.one_hot(fe, E, dtype=jnp.int32)     # [N*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot           # position in expert
    pie = jnp.sum(pos * onehot, axis=1)                 # [N*k]
    keep = pie < capacity
    pie_c = jnp.minimum(pie, capacity - 1)
    xg = jnp.zeros((E, capacity, d), xt.dtype)
    contrib = xt[tok] * keep[:, None].astype(xt.dtype)
    xg = xg.at[fe, pie_c].add(contrib)
    yg = _expert_ffn(p, xg)
    yflat = yg[fe, pie_c] * (fw * keep)[:, None].astype(xt.dtype)
    out = jnp.zeros((N, d), xt.dtype).at[tok].add(yflat)
    return out


def moe_ffn(p, cfg, x):
    """x [B, T, d] -> (out [B, T, d], aux_loss)."""
    B, T, d = x.shape
    weights, idx, aux = _route(p, cfg, x)
    weights = weights.astype(x.dtype)
    E, k, cf = cfg.num_experts, cfg.top_k, cfg.capacity_factor
    if T == 1:
        capacity = max(1, int(-(-B * k * cf // E)))
        out = _dispatch_combine(p, cfg, x[:, 0], weights[:, 0], idx[:, 0],
                                capacity)
        return out[:, None], aux
    capacity = max(1, int(-(-T * k * cf // E)))
    out = jax.vmap(
        lambda xr, wr, ir: _dispatch_combine(p, cfg, xr, wr, ir, capacity)
    )(x, weights, idx)
    return out, aux
