"""Assigned architecture ``granite-moe-3b-a800m``.

[moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.registry import GRANITE_MOE_3B as CONFIG, reduced_config

SMOKE = reduced_config('granite-moe-3b-a800m')
