"""Assigned architecture ``smollm-135m``.

[dense] 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]
"""
from repro.configs.registry import SMOLLM_135M as CONFIG, reduced_config

SMOKE = reduced_config('smollm-135m')
