"""Assigned architecture ``gemma3-1b``.

[dense] 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 — 5:1 local:global, 128k [hf:google/gemma-3-1b-pt]
"""
from repro.configs.registry import GEMMA3_1B as CONFIG, reduced_config

SMOKE = reduced_config('gemma3-1b')
