"""Assigned architecture ``qwen2.5-3b``.

[dense] 36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B]
"""
from repro.configs.registry import QWEN25_3B as CONFIG, reduced_config

SMOKE = reduced_config('qwen2.5-3b')
