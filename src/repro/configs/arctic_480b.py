"""Assigned architecture ``arctic-480b``.

[moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 — 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs.registry import ARCTIC_480B as CONFIG, reduced_config

SMOKE = reduced_config('arctic-480b')
