"""Assigned architecture ``zamba2-7b``.

[hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242]
"""
from repro.configs.registry import ZAMBA2_7B as CONFIG, reduced_config

SMOKE = reduced_config('zamba2-7b')
