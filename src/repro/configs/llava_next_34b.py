"""Assigned architecture ``llava-next-34b``.

[vlm] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.configs.registry import LLAVA_NEXT_34B as CONFIG, reduced_config

SMOKE = reduced_config('llava-next-34b')
