"""Assigned architecture ``qwen3-14b``.

[dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B]
"""
from repro.configs.registry import QWEN3_14B as CONFIG, reduced_config

SMOKE = reduced_config('qwen3-14b')
