"""Assigned architecture ``whisper-base``.

[audio] 6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865 — enc-dec, conv frontend (stub) [arXiv:2212.04356]
"""
from repro.configs.registry import WHISPER_BASE as CONFIG, reduced_config

SMOKE = reduced_config('whisper-base')
