"""Architecture registry: the 10 assigned architectures (+ the paper's own
Qwen2.5-14B/32B used in TaiChi's evaluation), exact numbers as assigned.

Each entry also defines a REDUCED variant (<=2 layers, d_model<=512,
<=4 experts) for CPU smoke tests, and ``input_specs`` /
``shape_applicability`` logic lives in repro.launch.specs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return sorted(_REGISTRY)


def reduced_config(name: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests: 2 layers,
    d_model<=512, <=4 experts."""
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=256,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=64,
        dtype="float32",
        remat=False,
    )
    if cfg.family in ("dense", "vlm"):
        kw.update(num_heads=4, num_kv_heads=max(1, cfg.num_kv_heads
                                                and min(2, cfg.num_kv_heads)))
    elif cfg.family == "moe":
        kw.update(num_heads=4, num_kv_heads=2, num_experts=4,
                  top_k=min(2, cfg.top_k), moe_d_ff=128)
    elif cfg.family in ("ssm", "hybrid"):
        kw.update(num_heads=4 if cfg.family == "hybrid" else 0,
                  num_kv_heads=4 if cfg.family == "hybrid" else 0,
                  ssm_state=16, ssm_headdim=32)
        if cfg.family == "hybrid":
            kw.update(attn_period=2, num_layers=4)
    elif cfg.family == "audio":
        kw.update(num_heads=4, num_kv_heads=4, num_encoder_layers=2)
    if cfg.family == "vlm":
        kw.update(num_image_tokens=16, vision_dim=64)
    if cfg.local_global_ratio:
        kw.update(local_global_ratio=cfg.local_global_ratio,
                  sliding_window=32, num_layers=8)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Assigned architectures (public-literature pool; citations in brackets)
# ---------------------------------------------------------------------------

# [hybrid] Mamba2 backbone + shared attention blocks [arXiv:2411.15242]
ZAMBA2_7B = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, attn_period=6,
    source="arXiv:2411.15242",
))

# [moe] 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]
ARCTIC_480B = register(ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
))

# [dense] GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B]
QWEN25_3B = register(ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, qkv_bias=True,
    source="hf:Qwen/Qwen2.5-0.5B",
))

# [dense] qk_norm, GQA [hf:Qwen/Qwen3-8B]
QWEN3_14B = register(ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=17408, vocab_size=151936, qk_norm=True, head_dim=128,
    source="hf:Qwen/Qwen3-8B",
))

# [audio] enc-dec, conv frontend (stub) [arXiv:2212.04356]
WHISPER_BASE = register(ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, num_encoder_layers=6,
    d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    source="arXiv:2212.04356",
))

# [vlm] anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf]
LLAVA_NEXT_34B = register(ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    num_image_tokens=2880, vision_dim=1152,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))

# [dense] 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt]
GEMMA3_1B = register(ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    sliding_window=1024, local_global_ratio=5,
    source="hf:google/gemma-3-1b-pt",
))

# [ssm] SSD (state-space duality), attn-free [arXiv:2405.21060]
MAMBA2_13B = register(ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64,
    source="arXiv:2405.21060",
))

# [dense] llama-arch small [hf:HuggingFaceTB/SmolLM-135M]
SMOLLM_135M = register(ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152,
    source="hf:HuggingFaceTB/SmolLM-135M",
))

# [moe] 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]
GRANITE_MOE_3B = register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=40, top_k=8, moe_d_ff=512,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))

# --- the paper's own evaluation models (TaiChi §4.1) ---
QWEN25_14B = register(ModelConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, qkv_bias=True, head_dim=128,
    source="hf:Qwen/Qwen2.5-14B (paper §4.1)",
))
QWEN25_32B = register(ModelConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064, qkv_bias=True, head_dim=128,
    source="hf:Qwen/Qwen2.5-32B (paper §4.1)",
))

ASSIGNED = [
    "zamba2-7b", "arctic-480b", "qwen2.5-3b", "qwen3-14b", "whisper-base",
    "llava-next-34b", "gemma3-1b", "mamba2-1.3b", "smollm-135m",
    "granite-moe-3b-a800m",
]
