"""Assigned architecture ``mamba2-1.3b``.

[ssm] 48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]
"""
from repro.configs.registry import MAMBA2_13B as CONFIG, reduced_config

SMOKE = reduced_config('mamba2-1.3b')
