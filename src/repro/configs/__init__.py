from repro.configs.registry import (ASSIGNED, get_config, list_archs,
                                    reduced_config, register)

__all__ = ["ASSIGNED", "get_config", "list_archs", "reduced_config",
           "register"]
